"""Block stacks: init/apply for the pattern-cycled layer architecture.

Layers are stacked per pattern position and iterated with ``lax.scan``
(one compiled block group regardless of depth — essential for compiling
80-layer configs in the dry-run).  Caches are stacked the same way and
threaded through the scan.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import rwkv as R
from repro.models.config import BlockSpec, ModelConfig

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _norm_init(cfg: ModelConfig):
    return (L.layernorm_init(cfg.d_model, cfg.pdtype)
            if cfg.norm == "layernorm" else
            L.norm_init(cfg.d_model, cfg.pdtype))


def _norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return L.layernorm(p, x, cfg.norm_eps)
    return L.rmsnorm(p, x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Single block
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, spec: BlockSpec,
               cross_attn: bool = False) -> Params:
    keys = jax.random.split(rng, 6)
    p: Params = {"ln1": _norm_init(cfg)}
    if spec.mixer == "attn":
        p["attn"] = A.init_attention(
            keys[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            cfg.qk_norm, cfg.pdtype)
    elif spec.mixer == "mamba":
        p["mamba"] = M.init_mamba(keys[0], cfg.d_model,
                                  cfg.mamba or M.MambaConfig(), cfg.pdtype)
    elif spec.mixer == "rwkv":
        p["rwkv_tm"] = R.init_time_mix(keys[0], cfg.d_model,
                                       cfg.rwkv or R.RwkvConfig(),
                                       cfg.pdtype)
    else:
        raise ValueError(spec.mixer)
    if cross_attn:
        p["ln_cross"] = _norm_init(cfg)
        p["cross_attn"] = A.init_attention(
            keys[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            cfg.qk_norm, cfg.pdtype)
    if spec.ffn != "none":
        p["ln2"] = _norm_init(cfg)
    if spec.ffn == "dense":
        p["mlp"] = L.mlp_init(keys[2], cfg.d_model, cfg.d_ff,
                              cfg.ffn_kind, cfg.pdtype)
    elif spec.ffn == "moe":
        assert cfg.moe is not None
        p["moe"] = MOE.init_moe(keys[2], cfg.d_model, cfg.moe, cfg.pdtype)
    elif spec.ffn == "rwkv_cm":
        p["rwkv_cm"] = R.init_channel_mix(keys[2], cfg.d_model, cfg.d_ff,
                                          cfg.pdtype)
    return p


def init_block_cache(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     max_len: int, cross_len: int = 0,
                     paged: Optional[Tuple] = None) -> Cache:
    """``paged=(num_pages, page_size[, kv_dtype])`` swaps the attention
    KV layout for the kvpool page-pool arrays (decode addresses them
    through a block table; ``batch``/``max_len`` are then ignored for
    attention).  The optional ``kv_dtype`` element overrides the page
    dtype — ``"int8"`` adds per-row scale rows (see
    ``attention.init_paged_kv_cache``).  Recurrent state (mamba/rwkv)
    is fixed-size per slot and has no paged form; enc-dec cross caches
    are likewise dense-only."""
    c: Cache = {}
    if spec.mixer == "attn":
        if paged is not None:
            if cross_len:
                raise NotImplementedError(
                    "paged KV does not cover enc-dec cross caches")
            c["attn"] = A.init_paged_kv_cache(
                paged[0], cfg.n_kv_heads, paged[1], cfg.d_head,
                jnp.dtype(cfg.cache_dtype),
                kv_dtype=paged[2] if len(paged) > 2 else None)
        else:
            c["attn"] = A.init_kv_cache(batch, cfg.n_kv_heads, max_len,
                                        cfg.d_head,
                                        jnp.dtype(cfg.cache_dtype))
    elif spec.mixer == "mamba":
        c["mamba"] = M.init_mamba_cache(batch, cfg.d_model,
                                        cfg.mamba or M.MambaConfig(),
                                        jnp.dtype(cfg.cache_dtype))
    elif spec.mixer == "rwkv":
        c["rwkv"] = R.init_rwkv_cache(batch, cfg.d_model,
                                      cfg.rwkv or R.RwkvConfig(),
                                      jnp.dtype(cfg.cache_dtype))
    if cross_len:
        c["cross"] = A.init_kv_cache(batch, cfg.n_kv_heads, cross_len,
                                     cfg.d_head, jnp.dtype(cfg.cache_dtype))
    return c


def apply_block(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    spec: BlockSpec,
    *,
    positions: Optional[jax.Array],
    cache: Optional[Cache] = None,
    cache_pos: Optional[jax.Array] = None,
    block_tables: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,
    decode: bool = False,
) -> Tuple[jax.Array, Optional[Cache], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Cache = {} if cache is not None else None

    h = _norm(cfg, p["ln1"], x)
    if spec.mixer == "attn":
        out, nc = A.attention(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            d_head=cfg.d_head, positions=positions,
            rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
            qk_norm=cfg.qk_norm, causal=cfg.causal,
            cache=None if cache is None else cache.get("attn"),
            cache_pos=cache_pos, block_tables=block_tables)
        if new_cache is not None and nc is not None:
            new_cache["attn"] = nc
    elif spec.mixer == "mamba":
        out, nc = M.mamba_forward(p["mamba"], h, cfg.mamba or M.MambaConfig(),
                                  None if cache is None
                                  else cache.get("mamba"))
        if new_cache is not None and nc is not None:
            new_cache["mamba"] = nc
    else:  # rwkv
        out, nc = R.time_mix(p["rwkv_tm"], h, cfg.rwkv or R.RwkvConfig(),
                             None if cache is None else cache.get("rwkv"))
        if new_cache is not None and nc is not None:
            new_cache["rwkv"] = nc
    # Post-collective output: the row-parallel combine's result.  Named so
    # the "tp_outs" remat policy can save exactly these (backward then
    # never re-runs the forward all-reduces).
    x = x + checkpoint_name(out, "tp_out")

    if "cross_attn" in p:
        h = _norm(cfg, p["ln_cross"], x)
        cross_cache = None if cache is None else cache.get("cross")
        out, nc = A.attention(
            p["cross_attn"], h, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head, positions=None,
            qk_norm=cfg.qk_norm, causal=False,
            cache=cross_cache, cache_pos=None if decode else 0,
            kv_from=None if decode else enc_out,
            use_cached_kv=decode)
        if new_cache is not None and nc is not None:
            new_cache["cross"] = nc
        x = x + out

    if spec.ffn != "none":
        h = _norm(cfg, p["ln2"], x)
        if spec.ffn == "dense":
            x = x + checkpoint_name(L.mlp(p["mlp"], h, cfg.ffn_kind),
                                    "tp_out")
        elif spec.ffn == "moe":
            out, aux = MOE.moe_ffn(p["moe"], h, cfg.moe)
            x = x + checkpoint_name(out, "tp_out")
        elif spec.ffn == "rwkv_cm":
            out, nc = R.channel_mix(
                p["rwkv_cm"], h,
                None if cache is None else cache.get("rwkv"))
            if new_cache is not None and nc is not None:
                # Merge channel-mix shift state into the rwkv cache entry.
                merged = dict(new_cache.get("rwkv", cache.get("rwkv")))
                merged["shift_cm"] = nc["shift_cm"]
                new_cache["rwkv"] = merged
            x = x + out
    x = L.shard_hint(x, "residual")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stacks (scan over groups)
# ---------------------------------------------------------------------------


def init_stack(rng, cfg: ModelConfig, cross_attn: bool = False
               ) -> List[Params]:
    """Per pattern position: params stacked over n_groups (leading axis)."""
    stacks = []
    for i, spec in enumerate(cfg.pattern):
        rngs = jax.random.split(jax.random.fold_in(rng, i), cfg.n_groups)
        stacks.append(jax.vmap(
            lambda r, s=spec: init_block(r, cfg, s, cross_attn))(rngs))
    return stacks


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int,
                     cross_len: int = 0,
                     paged: Optional[Tuple] = None) -> List[Cache]:
    caches = []
    for spec in cfg.pattern:
        one = init_block_cache(cfg, spec, batch, max_len, cross_len,
                               paged=paged)
        caches.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_groups,) + x.shape), one))
    return caches


def apply_stack(
    stacks: List[Params],
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array],
    caches: Optional[List[Cache]] = None,
    cache_pos: Optional[jax.Array] = None,
    block_tables: Optional[jax.Array] = None,
    enc_out: Optional[jax.Array] = None,
    decode: bool = False,
    remat: bool = False,
    remat_policy: str = "full",
) -> Tuple[jax.Array, Optional[List[Cache]], jax.Array]:
    """Scan the group over n_groups.  Returns (x, new_caches, aux_sum).

    remat_policy: "full" saves nothing (max recompute — the backward
    re-executes the forward *including its partial-sum all-reduces*);
    "dots" saves matmul outputs (jax.checkpoint_policies.checkpoint_dots)
    so the collective results survive to the backward — the §Perf lever
    that removes the remat-duplicated collectives.
    """

    def group_fn(carry, xs):
        x, aux = carry
        params_g = xs["params"]
        caches_g = xs.get("cache")
        new_caches_g = [] if caches_g is not None else None
        for i, spec in enumerate(cfg.pattern):
            c = None if caches_g is None else caches_g[i]
            x, nc, a = apply_block(
                params_g[i], x, cfg, spec, positions=positions, cache=c,
                cache_pos=cache_pos, block_tables=block_tables,
                enc_out=enc_out, decode=decode)
            aux = aux + a
            if new_caches_g is not None:
                new_caches_g.append(nc if nc else c)
        out = {"cache": new_caches_g} if new_caches_g is not None else {}
        return (x, aux), out

    if remat:
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots
        elif remat_policy == "tp_outs":
            # Save only the named post-collective block outputs: the
            # backward re-runs elementwise/attention work but never the
            # partial-sum combines — minimal memory for maximal
            # collective savings.
            policy = jax.checkpoint_policies.save_only_these_names(
                "tp_out")
        else:
            policy = None
        fn = jax.checkpoint(group_fn, policy=policy)
    else:
        fn = group_fn
    xs = {"params": stacks}
    if caches is not None:
        xs["cache"] = caches
    (x, aux), ys = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), xs)
    new_caches = ys.get("cache") if caches is not None else None
    return x, new_caches, aux
