"""Model zoo substrate: pattern-cycled blocks covering dense GQA
transformers, MoE, Mamba, RWKV6, encoder-decoder and VLM backbones."""

from repro.models.config import BlockSpec, ModelConfig
from repro.models.model import (decode_step, forward, init_cache,
                                init_paged_cache, init_params, loss_fn,
                                paged_eligible, param_count, prefill)

__all__ = ["BlockSpec", "ModelConfig", "decode_step", "forward",
           "init_cache", "init_paged_cache", "init_params", "loss_fn",
           "paged_eligible", "param_count", "prefill"]
