"""Mixture-of-Experts FFN: top-k routing, capacity, scatter dispatch.

Capacity-based top-k routing (GShard/Switch style) implemented with
scatter/gather so it shards cleanly under GSPMD: tokens are sharded over
`data`, the expert dimension over `model` (expert parallelism); the
scatter into the (E, C, d) expert buffers lowers to the dispatch
all-to-all on a real mesh.

Supports top-1 (llama4-maverick / Switch) through top-8 (kimi-k2), a
shared-expert branch (DeepSeek/Kimi style), and a load-balancing auxiliary
loss.  Dropped tokens (over capacity) fall through via the residual
connection, as in GShard.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.serving.quant import maybe_dequant

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden size
    capacity_factor: float = 1.25
    min_capacity: int = 4
    n_shared: int = 0              # shared (always-on) experts
    shared_d_ff: int = 0           # hidden size of the shared branch
    aux_loss_weight: float = 0.01
    # Combine formulation: "gather" indexes the (E, C, d) expert outputs
    # by token (GSPMD all-gathers the expert-sharded operand — ~7 TB/dev
    # per kimi-k2 train step); "scatter" writes each expert's slots back
    # into the token buffer (updates stay expert-sharded; GSPMD emits
    # local scatters + one (T, d) partial-sum combine).  §Perf lever.
    combine: str = "scatter"
    # GShard-style dispatch groups: routing/capacity computed per group of
    # T/G tokens (aligned with the data shards) instead of globally.  Cuts
    # the O(T*k*E) position-cumsum to per-group parallel scans and keeps
    # the dispatch scatter group-local — the §Perf hillclimb lever for the
    # MoE architectures.  1 = the paper-faithful global dispatch.
    dispatch_groups: int = 1


def init_moe(rng, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> Params:
    rr, rg, ru, rd, rs = jax.random.split(rng, 5)
    e, f = cfg.num_experts, cfg.d_ff
    scale = (1.0 / d_model) ** 0.5
    p = {
        "router": L.dense_init(rr, d_model, e, jnp.float32),
        "gate": jax.random.normal(rg, (e, d_model, f), dtype) * scale,
        "up": jax.random.normal(ru, (e, d_model, f), dtype) * scale,
        "down": jax.random.normal(rd, (e, f, d_model), dtype)
        * (1.0 / f) ** 0.5,
    }
    if cfg.n_shared:
        shared_ff = cfg.shared_d_ff or cfg.d_ff * cfg.n_shared
        p["shared"] = L.mlp_init(rs, d_model, shared_ff, "swiglu", dtype)
    return p


def capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.top_k / cfg.num_experts)
    return max(cfg.min_capacity, c)


def moe_ffn(p: Params, x: jax.Array, cfg: MoEConfig
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    groups = cfg.dispatch_groups if t % max(cfg.dispatch_groups, 1) == 0 \
        else 1
    tg = t // groups
    cap = capacity(tg, cfg)

    xt = L.shard_hint(x.reshape(t, d), "tokens2d")
    xg = xt.reshape(groups, tg, d)

    router_logits = L.dense(p["router"],
                            xg.astype(jnp.float32))          # (G, Tg, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                 # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Position of each (token, slot) within its expert's (per-group)
    # capacity buffer: cumsum over the group's token axis.
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)         # (G,Tg,k,E)
    flat = onehot.reshape(groups, tg * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                       # (G,Tg*k,E)
    pos_in_e = jnp.sum(pos * flat, axis=-1).reshape(groups, tg, k)
    keep = pos_in_e < cap

    # Scatter tokens into (G, E, C, d) expert buffers (the EP dispatch;
    # lowers to the all-to-all on a real mesh).
    safe_pos = jnp.where(keep, pos_in_e, cap - 1)
    contrib = jnp.where(keep[..., None], 1.0, 0.0).astype(x.dtype)
    gidx = jnp.broadcast_to(
        jnp.arange(groups, dtype=jnp.int32)[:, None, None], idx.shape)
    expert_in = jnp.zeros((groups, e, cap, d), x.dtype)
    expert_in = expert_in.at[gidx, idx, safe_pos].add(
        contrib * xg[:, :, None, :], mode="drop")
    expert_in = L.shard_hint(expert_in, "experts")

    # Per-expert SwiGLU (batched einsum; E shards over the model axis,
    # G over the data axes).
    g = jnp.einsum("gecd,edf->gecf", expert_in, maybe_dequant(p["gate"], x.dtype))
    u = jnp.einsum("gecd,edf->gecf", expert_in, maybe_dequant(p["up"], x.dtype))
    h = jax.nn.silu(g) * u
    h = L.shard_hint(h, "experts")
    expert_out = L.shard_hint(
        jnp.einsum("gecf,efd->gecd", h, maybe_dequant(p["down"], x.dtype)),
        "experts")

    weights = (gate_vals * keep).astype(x.dtype)             # (G,Tg,k)
    if cfg.combine == "gather":
        # Index expert outputs by token (simple, but the expert-sharded
        # operand gets all-gathered to every device).
        out_slots = expert_out[gidx, idx, safe_pos]          # (G,Tg,k,d)
        out = jnp.einsum("gtkd,gtk->gtd", out_slots, weights).reshape(t, d)
    else:
        # Scatter-combine: record which token (and gate weight) owns each
        # capacity slot during dispatch, then push every expert's slots
        # back into the token buffer.  Updates are expert-sharded; unfilled
        # slots carry weight 0 and token id 0 (contribute nothing).
        tok_ids = jnp.broadcast_to(
            jnp.arange(tg, dtype=jnp.int32)[None, :, None], idx.shape)
        slot_token = jnp.zeros((groups, e, cap), jnp.int32)
        slot_token = slot_token.at[gidx, idx, safe_pos].max(
            jnp.where(keep, tok_ids, 0), mode="drop")
        slot_w = jnp.zeros((groups, e, cap), x.dtype)
        slot_w = slot_w.at[gidx, idx, safe_pos].add(
            jnp.where(keep, weights, 0.0), mode="drop")
        gix = jnp.broadcast_to(
            jnp.arange(groups, dtype=jnp.int32)[:, None, None],
            slot_token.shape)
        outg = jnp.zeros((groups, tg, d), x.dtype)
        outg = outg.at[gix, slot_token].add(
            expert_out * slot_w[..., None], mode="drop")
        out = outg.reshape(t, d)
    out = L.shard_hint(out, "tokens2d")

    if cfg.n_shared:
        out = out + L.mlp(p["shared"], xt, "swiglu")

    # Load-balance auxiliary loss (Switch): E * sum(frac_tokens * frac_prob).
    me = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32),
                  axis=(0, 1))
    pe = jnp.mean(probs, axis=(0, 1))
    aux = cfg.aux_loss_weight * e * jnp.sum(me * pe)

    return out.reshape(b, s, d), aux
