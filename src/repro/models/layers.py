"""Shared layers: norms, rotary embeddings (incl. M-RoPE), MLPs, embeddings.

All layers are pure functions over param dicts (pytrees).  Every matmul
routes through :func:`gemm` so the GAMA Pallas kernel can be swapped in on
TPU (models default to jnp for CPU smoke tests and the dry-run, which is
mathematically identical — see kernels/ops.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.serving.quant import maybe_dequant

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# GEMM indirection — the GAMA integration point
# ---------------------------------------------------------------------------

_GEMM_MODE = "ref"   # "ref" (jnp) | "kernel" (Pallas) — set by set_gemm_mode


def set_gemm_mode(mode: str) -> None:
    global _GEMM_MODE
    assert mode in ("ref", "kernel", "auto")
    _GEMM_MODE = mode


# Activation-sharding hook: the launcher installs a policy callback
# (ShardingPolicy.act) and model code marks tensors with semantic kinds
# ("residual", "heads", "channels", ...).  Identity when unset (smoke
# tests, single-device runs).  GSPMD needs these hints at the points
# where reshapes make propagation ambiguous (e.g. head splits that do
# not divide the model axis) — without them it falls back to replication.
_SHARD_HOOK = None


def set_shard_hook(fn) -> None:
    global _SHARD_HOOK
    _SHARD_HOOK = fn


def shard_hint(x: jax.Array, kind: str) -> jax.Array:
    if _SHARD_HOOK is None:
        return x
    return _SHARD_HOOK(x, kind)


def gemm(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., K) @ w: (K, N) -> (..., N), via the GAMA kernel when on.

    With a pack context installed (``repro.distributed.pack_gemm``),
    GEMMs above its FLOP threshold — in practice the lm head and the
    ffn projections — route through the pack-level collective matmul
    even when the Pallas kernel is off (the local per-device GEMMs then
    use the jnp reference, mode="auto").  The pack context therefore
    outranks ``set_gemm_mode("ref")`` here: to isolate the pure
    single-process oracle for numerics debugging, clear the context
    (``pack_gemm.clear_pack_context()`` / ``engine.close()``) or call
    ``kernels.ops.matmul(..., mode="ref")`` directly.
    """
    rows = math.prod(x.shape[:-1])
    use_kernel = _GEMM_MODE == "kernel" or (
        _GEMM_MODE == "auto" and kops.on_tpu())
    if not use_kernel and not kops.pack_eligible(rows, x.shape[-1],
                                                 w.shape[-1]):
        return x @ w
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    out = kops.matmul(x2, w, mode="kernel" if use_kernel else "auto")
    return out.reshape(*lead, w.shape[-1])


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(rng, d_in: int, d_out: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> Params:
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return {"w": jax.random.normal(rng, (d_in, d_out), dtype) * scale}


def dense(p: Params, x: jax.Array) -> jax.Array:
    y = gemm(x, maybe_dequant(p["w"], x.dtype))
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(dt)


def groupnorm(x: jax.Array, n_groups: int, scale: jax.Array,
              bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    """GroupNorm over the channel dim (used by RWKV's wkv output)."""
    dt = x.dtype
    *lead, d = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, d)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, d_head: int,
                theta: float = 10000.0) -> jax.Array:
    """positions: (..., S) -> angles (..., S, d_head//2)."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, d_head, 2,
                                           dtype=jnp.float32) / d_head))
    return positions[..., None].astype(jnp.float32) * inv_freq


def mrope_angles(positions: jax.Array, d_head: int,
                 sections: Sequence[int],
                 theta: float = 10000.0) -> jax.Array:
    """M-RoPE (Qwen2-VL): positions (..., S, 3) = (t, h, w) coordinates.

    The d_head//2 frequency slots are split into `sections` (t, h, w
    section sizes, summing to d_head//2); each section rotates by its own
    coordinate.  Text tokens use t == h == w, recovering standard RoPE.
    """
    half = d_head // 2
    assert sum(sections) == half, (sections, d_head)
    inv_freq = 1.0 / (theta ** (jnp.arange(0, d_head, 2,
                                           dtype=jnp.float32) / d_head))
    # Which coordinate (0=t, 1=h, 2=w) each frequency slot rotates by.
    select = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                        total_repeat_length=half)
    pos_sel = positions[..., select]            # (..., S, half)
    return pos_sel.astype(jnp.float32) * inv_freq


# RoPE application dtype: "float32" (default, max accuracy) or "compute"
# (multiply in the activation dtype — halves the bytes of any collective
# XLA hoists across the rotation; angles/sin/cos stay f32).  §Perf lever.
_ROPE_DTYPE = "float32"


def set_rope_dtype(mode: str) -> None:
    global _ROPE_DTYPE
    assert mode in ("float32", "compute")
    _ROPE_DTYPE = mode


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, H, D); angles: (B, S, D//2) (broadcast over heads)."""
    dt = x.dtype
    wdt = jnp.float32 if _ROPE_DTYPE == "float32" else dt
    xf = x.astype(wdt)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    cos = jnp.cos(angles).astype(wdt)[..., None, :]
    sin = jnp.sin(angles).astype(wdt)[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_init(rng, d_model: int, d_ff: int, kind: str = "swiglu",
             dtype=jnp.float32) -> Params:
    r1, r2, r3 = jax.random.split(rng, 3)
    p = {"down": dense_init(r2, d_ff, d_model, dtype)}
    if kind == "swiglu":
        p["gate"] = dense_init(r1, d_model, d_ff, dtype)
        p["up"] = dense_init(r3, d_model, d_ff, dtype)
    else:
        p["up"] = dense_init(r1, d_model, d_ff, dtype)
    return p


def mlp(p: Params, x: jax.Array, kind: str = "swiglu") -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    else:
        h = jax.nn.gelu(dense(p["up"], x))
    h = shard_hint(h, "channels")
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(rng, vocab: int, d_model: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(rng, (vocab, d_model), dtype) * 0.02}


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return maybe_dequant(p["table"], dtype)[tokens]


def logits(p: Params, x: jax.Array, head: Optional[Params]) -> jax.Array:
    """Tied (embed.T) or separate head; returns f32 logits."""
    if head is not None:
        out = dense(head, x).astype(jnp.float32)
    else:
        out = gemm(x, maybe_dequant(p["table"], x.dtype).T).astype(
            jnp.float32)
    return shard_hint(out, "logits")


def cross_entropy(logits_: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy; logits (B,S,V) f32, labels (B,S) int."""
    logp = jax.nn.log_softmax(logits_, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
