"""Grouped-query attention with RoPE / M-RoPE, qk-norm, and KV caching.

Supports the five execution shapes the assignment exercises:
  * train:   full causal self-attention, no cache;
  * prefill: causal self-attention that also writes the KV cache;
  * chunked prefill: a prompt *chunk* at its cursor offset — scalar
    ``cache_pos > 0`` with S > 1 writes the chunk's KV at the offset and
    attends causally over the cache's grown prefix (``q_offset`` keys
    the causal mask to absolute positions, RoPE angles come from the
    caller's offset positions), so a prompt prefilled chunk-by-chunk is
    bit-identical to one monolithic prefill;
  * decode:  one new token against a cached KV prefix (flash-decode path);
  * cross:   encoder-decoder cross attention (cache holds encoder KV).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers as L

Params = Dict[str, Any]


def init_attention(rng, d_model: int, n_heads: int, n_kv_heads: int,
                   d_head: int, qk_norm: bool = False,
                   dtype=jnp.float32) -> Params:
    rq, rk, rv, ro = jax.random.split(rng, 4)
    p = {
        "wq": L.dense_init(rq, d_model, n_heads * d_head, dtype),
        "wk": L.dense_init(rk, d_model, n_kv_heads * d_head, dtype),
        "wv": L.dense_init(rv, d_model, n_kv_heads * d_head, dtype),
        "wo": L.dense_init(ro, n_heads * d_head, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = L.norm_init(d_head, dtype)
        p["k_norm"] = L.norm_init(d_head, dtype)
    return p


def init_kv_cache(batch: int, n_kv_heads: int, max_len: int, d_head: int,
                  dtype=jnp.bfloat16) -> Params:
    return {
        "k": jnp.zeros((batch, n_kv_heads, max_len, d_head), dtype),
        "v": jnp.zeros((batch, n_kv_heads, max_len, d_head), dtype),
    }


def init_paged_kv_cache(num_pages: int, n_kv_heads: int, page_size: int,
                        d_head: int, dtype=jnp.bfloat16,
                        kv_dtype: Optional[str] = None) -> Params:
    """Paged pool layout (``repro.serving.kvpool``): ``num_pages`` blocks
    of ``page_size`` tokens shared by every slot, addressed through a
    per-slot block table.  ``num_pages`` must already include the null
    sink page (the engine allocates pool + 1).

    ``kv_dtype`` overrides the page dtype (``ServeConfig.kv_dtype``):
    a float name just retypes the pools; ``"int8"`` adds per-row f32
    scale-row arrays (``k_scale``/``v_scale``, one symmetric scale per
    token row per KV head) — the quantized-page layout the fused-dequant
    decode kernel consumes."""
    page_dtype = jnp.dtype(kv_dtype) if kv_dtype else jnp.dtype(dtype)
    cache = {
        "k_pages": jnp.zeros((num_pages, n_kv_heads, page_size, d_head),
                             page_dtype),
        "v_pages": jnp.zeros((num_pages, n_kv_heads, page_size, d_head),
                             page_dtype),
    }
    if page_dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros((num_pages, n_kv_heads, page_size),
                                     jnp.float32)
        cache["v_scale"] = jnp.zeros((num_pages, n_kv_heads, page_size),
                                     jnp.float32)
    return cache


def attention(
    p: Params,
    x: jax.Array,                       # (B, S, d_model)
    *,
    n_heads: int,
    n_kv_heads: int,
    d_head: int,
    positions: Optional[jax.Array] = None,     # (B, S) or (B, S, 3) M-RoPE
    rope_theta: float = 10000.0,
    mrope_sections: Optional[Tuple[int, ...]] = None,
    qk_norm: bool = False,
    causal: bool = True,
    cache: Optional[Params] = None,
    cache_pos: Optional[jax.Array] = None,      # scalar or (B,) write offset
    block_tables: Optional[jax.Array] = None,   # (B, max_pages) paged KV
    kv_from: Optional[jax.Array] = None,        # encoder states (cross-attn)
    use_cached_kv: bool = False,                # decode-time cross attention
    attn_mode: str = "auto",
) -> Tuple[jax.Array, Optional[Params]]:
    """Returns (output (B, S, d_model), updated cache)."""
    b, s, _ = x.shape
    q = L.dense(p["wq"], x).reshape(b, s, n_heads, d_head)

    if use_cached_kv:
        # Cross-attention after prefill: KV was computed from the encoder
        # once and lives in the cache; no projection, no cache update.
        assert cache is not None
        if qk_norm:
            q = L.rmsnorm(p["q_norm"], q)
        q = q.transpose(0, 2, 1, 3)
        k = cache["k"].astype(x.dtype)
        v = cache["v"].astype(x.dtype)
        if s == 1:
            length = jnp.full((b,), k.shape[2], jnp.int32)
            out = kops.decode(q[:, :, 0], k, v, length=length,
                              mode=attn_mode)[:, :, None]
        else:
            out = kops.attention(q, k, v, causal=False, mode=attn_mode)
        out = out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * d_head)
        return L.dense(p["wo"], out), cache

    kv_src = x if kv_from is None else kv_from
    sk = kv_src.shape[1]
    k = L.dense(p["wk"], kv_src).reshape(b, sk, n_kv_heads, d_head)
    v = L.dense(p["wv"], kv_src).reshape(b, sk, n_kv_heads, d_head)

    if qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
        k = L.rmsnorm(p["k_norm"], k)

    use_rope = kv_from is None and positions is not None
    if use_rope:
        if mrope_sections is not None:
            angles = L.mrope_angles(positions, d_head, mrope_sections,
                                    rope_theta)
        else:
            angles = L.rope_angles(positions, d_head, rope_theta)
        q = L.apply_rope(q, angles)
        k = L.apply_rope(k, angles)

    q = L.shard_hint(q.transpose(0, 2, 1, 3), "heads")    # (B, H, S, D)
    k = L.shard_hint(k.transpose(0, 2, 1, 3), "heads")    # (B, Hkv, Sk, D)
    v = L.shard_hint(v.transpose(0, 2, 1, 3), "heads")

    new_cache = None
    ragged = getattr(cache_pos, "ndim", 0) == 1   # per-slot positions
    if ragged and s != 1:
        raise NotImplementedError(
            "per-slot cache_pos is a decode-only shape (S == 1); prefill "
            "admits one request (or one prompt chunk) at a time at its "
            "own scalar offset")
    paged = cache is not None and "k_pages" in cache
    if paged:
        # Paged KV (kvpool): decode-only — prefill runs against a dense
        # single-slot cache whose pages the engine scatters into the
        # pool.  The new token's KV row lands at row pos % page_size of
        # page block_tables[b, pos // page_size]; page ids are unique
        # per live slot (free slots share the null sink, whose garbage
        # is unreachable: their length masks everything).
        if not ragged or block_tables is None:
            raise NotImplementedError(
                "paged KV attention needs per-slot cache_pos and "
                "block_tables (the continuous-batching decode shape)")
        page_size = cache["k_pages"].shape[2]
        pos = jnp.asarray(cache_pos, jnp.int32)
        page_ids = block_tables[jnp.arange(b), pos // page_size]
        rows = pos % page_size
        length = pos + 1
        if "k_scale" in cache:
            # int8 pages: quantize exactly the row being appended (per-
            # row symmetric scales — no existing row is requantized) and
            # write its scale into the pool's scale rows.  The decode
            # kernel dequantizes inside its split-K page loop.
            from repro.serving.quant import quantize_kv_row
            kq, ksc = quantize_kv_row(k[:, :, 0])
            vq, vsc = quantize_kv_row(v[:, :, 0])
            ck = cache["k_pages"].at[page_ids, :, rows, :].set(kq)
            cv = cache["v_pages"].at[page_ids, :, rows, :].set(vq)
            cks = cache["k_scale"].at[page_ids, :, rows].set(ksc)
            cvs = cache["v_scale"].at[page_ids, :, rows].set(vsc)
            new_cache = {"k_pages": ck, "v_pages": cv,
                         "k_scale": cks, "v_scale": cvs}
            out = kops.decode_paged(q[:, :, 0], ck, cv,
                                    block_tables=block_tables,
                                    length=length, k_scale=cks,
                                    v_scale=cvs, mode=attn_mode)
        else:
            ck = cache["k_pages"].at[page_ids, :, rows, :].set(
                k[:, :, 0].astype(cache["k_pages"].dtype))
            cv = cache["v_pages"].at[page_ids, :, rows, :].set(
                v[:, :, 0].astype(cache["v_pages"].dtype))
            new_cache = {"k_pages": ck, "v_pages": cv}
            out = kops.decode_paged(q[:, :, 0], ck.astype(x.dtype),
                                    cv.astype(x.dtype),
                                    block_tables=block_tables,
                                    length=length, mode=attn_mode)
        out = out[:, :, None].transpose(0, 2, 1, 3)   # (B, 1, H, D)
        out = out.reshape(b, s, n_heads * d_head)
        out = L.shard_hint(out, "channels")
        return L.dense(p["wo"], out), new_cache
    if cache is not None:
        if ragged:
            # Continuous batching: each slot writes its new KV row at its
            # own position.  vmap over the batch axis so the update is a
            # per-slot dynamic_update_slice, not one shared offset.
            def _write(dst, upd, p):
                return jax.lax.dynamic_update_slice(dst, upd, (0, p, 0))
            ck = jax.vmap(_write)(cache["k"], k.astype(cache["k"].dtype),
                                  cache_pos)
            cv = jax.vmap(_write)(cache["v"], v.astype(cache["v"].dtype),
                                  cache_pos)
        else:
            pos = 0 if cache_pos is None else cache_pos
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, pos, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, pos, 0))
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(x.dtype), cv.astype(x.dtype)

    if s == 1 and cache is not None:
        # Decode: one token against the cached prefix.  With per-slot
        # positions each slot's valid length differs — the decode kernel
        # masks attention past each slot's own length.
        length = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32) + 1,
                                  (b,))
        out = kops.decode(q[:, :, 0], k, v, length=length, mode=attn_mode)
        out = out[:, :, None]                       # (B, H, 1, D)
    else:
        q_off = 0 if cache_pos is None else cache_pos
        out = kops.attention(q, k, v, causal=causal and kv_from is None,
                             q_offset=q_off, mode=attn_mode)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, n_heads * d_head)
    out = L.shard_hint(out, "channels")
    return L.dense(p["wo"], out), new_cache
